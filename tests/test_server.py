"""Online serving front end + typed construction API (ISSUE 8 gates).

* ``EngineSpec`` validation fails fast with the offending field named;
  ``from_args`` maps the launcher flag surface (``--full`` replacing
  the unreachable-full ``--tiny``, ``tier_dtypes`` string parsing)
* ``build_engine`` is bit-equivalent to the deprecated
  ``executor_kwargs`` construction path, which must warn
* cancellation at every lifecycle point: mid-queue (scheduler removal +
  prefetch-ticket retraction), mid-decode (row masked, shared-run
  readers released, pool conservation holds, the surviving request's
  output stays bit-identical to an uncancelled run)
* per-token streaming: tokens arrive incrementally across engine steps
  and concatenate to exactly the non-streamed output
* the HTTP server end-to-end: submit/stream/cancel/health/stats over a
  real socket, streamed tokens bit-identical to ``Engine.run``
* session-structured workloads: independent per-session prefixes,
  multi-turn history growth, deterministic tenant assignment, and the
  determinism contract (single-turn configs leave the legacy main-rng
  stream untouched)
"""
import threading
from argparse import Namespace

import jax
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models import model as M
from repro.serving.api import (EngineSpec, StoreSpec, build_engine,
                               build_store)
from repro.serving.engine import Engine
from repro.serving.rag import KnowledgeBase
from repro.serving.request import Request, State
from repro.serving.scheduler import SchedulerConfig
from repro.serving.workload import (TenantSpec, WorkloadConfig,
                                    generate)


@pytest.fixture(scope="module")
def world():
    cfg = get_tiny("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    kb = KnowledgeBase(num_chunks=12, vocab_size=cfg.vocab_size, seed=0)
    return cfg, params, kb


def _spec(**kw):
    kw.setdefault("strategy", "all")
    kw.setdefault("use_focus", False)
    kw.setdefault("pool_blocks", 512)
    kw.setdefault("sched", SchedulerConfig(max_batch_tokens=100_000,
                                           max_decode_batch=4,
                                           max_prefill_batch=1))
    return EngineSpec(**kw)


def _requests(kb, n=2, max_new=4, seed=5, shared_chunks=False):
    rng = np.random.default_rng(seed)
    V = kb.vocab_size
    chunks = [kb.chunks[0], kb.chunks[1]]
    out = []
    for i in range(n):
        if not shared_chunks:
            chunks = [kb.chunks[(2 * i) % len(kb.chunks)],
                      kb.chunks[(2 * i + 1) % len(kb.chunks)]]
        out.append(Request(
            rid=i, system_tokens=rng.integers(0, V, 8).astype(np.int32),
            chunk_tokens=[c.copy() for c in chunks],
            question_tokens=rng.integers(0, V, 10).astype(np.int32),
            max_new_tokens=max_new, arrival_time=0.0))
    return out


# ---- EngineSpec validation ---------------------------------------------------
@pytest.mark.parametrize("kw,err,match", [
    (dict(strategy="nope"), ValueError, "strategy"),
    (dict(attn_impl="nope"), ValueError, "attn_impl"),
    (dict(pool_blocks=0), ValueError, "pool_blocks"),
    (dict(force_recompute_fraction=1.5), ValueError,
     "force_recompute_fraction"),
    (dict(sched={"max_decode_batch": 4}), TypeError, "sched"),
    (dict(store=StoreSpec(tier_dtypes={"cpu": "int4"})), ValueError,
     "tier_dtypes"),
    (dict(store=StoreSpec(hbm_bytes=0)), ValueError, "capacities"),
    (dict(store={"n_chunks": 5}), TypeError, "store"),
])
def test_spec_validation_names_the_field(kw, err, match):
    with pytest.raises(err, match=match):
        EngineSpec(**kw).validate()


def test_spec_from_args_flag_surface():
    # empty namespace -> pure defaults (every flag optional)
    spec = EngineSpec.from_args(Namespace())
    assert spec.tiny and spec.use_focus
    assert spec.strategy == "cachecraft" and spec.store is not None

    spec = EngineSpec.from_args(Namespace(
        full=True, no_focus=True, strategy="cachecraft", recompute=0.3,
        pool_blocks=2048, max_batch_tokens=4096, max_decode_batch=8,
        tier_dtypes="cpu=int8, ssd=fp8"))
    assert spec.tiny is False          # --full reachable again
    assert spec.use_focus is False
    assert spec.force_recompute_fraction == 0.3
    assert spec.pool_blocks == 2048
    assert spec.sched.max_batch_tokens == 4096
    assert spec.sched.max_decode_batch == 8
    assert spec.store.tier_dtypes == {"cpu": "int8", "ssd": "fp8"}

    # full recompute never takes a store
    assert EngineSpec.from_args(Namespace(strategy="all")).store is None

    with pytest.raises(ValueError, match="strategy"):
        EngineSpec.from_args(Namespace(strategy="bogus"))


def test_build_store_respects_spec(tmp_path):
    store = build_store(StoreSpec(ssd_dir=str(tmp_path / "s"),
                                  n_chunks=7, m_variants=2,
                                  start_worker=False))
    assert store.n_chunks == 7 and store.m_variants == 2
    assert build_store(None) is None


# ---- deprecated executor_kwargs alias ---------------------------------------
def test_executor_kwargs_deprecated_but_equivalent(world):
    cfg, params, kb = world
    reqs_new = _requests(kb)
    reqs_old = _requests(kb)
    eng_new = build_engine(_spec(), cfg=cfg, params=params, store=None)
    with pytest.warns(DeprecationWarning, match="executor_kwargs"):
        eng_old = Engine(
            cfg, params, None,
            sched=SchedulerConfig(max_batch_tokens=100_000,
                                  max_decode_batch=4,
                                  max_prefill_batch=1),
            pool_blocks=512,
            executor_kwargs=dict(strategy="all", use_focus=False))
    eng_new.run(reqs_new)
    eng_old.run(reqs_old)
    for a, b in zip(reqs_new, reqs_old):
        assert a.state == State.DONE
        assert a.output_tokens == b.output_tokens


# ---- cancellation ------------------------------------------------------------
def test_cancel_mid_queue_retracts_prefetch(world, tmp_path):
    cfg, params, kb = world
    store = build_store(StoreSpec(ssd_dir=str(tmp_path / "s"),
                                  n_chunks=50, m_variants=4,
                                  start_worker=False))
    eng = build_engine(_spec(strategy="cachecraft"), cfg=cfg,
                       params=params, store=store)
    reqs = _requests(kb, n=3)
    for r in reqs:
        eng.submit(r)
    eng.step()                  # admits reqs[0]; lookahead prefetches
    victim = reqs[2]
    assert victim.state == State.QUEUED
    ticket = victim.prefetch_ticket
    assert ticket is not None and not ticket.cancelled

    before = eng.counters.prefetch_cancels
    assert eng.cancel(victim.rid)
    assert victim.state == State.CANCELLED
    assert ticket.cancelled                   # promotions retracted
    assert victim.prefetch_ticket is None
    assert eng.counters.prefetch_cancels == before + 1
    assert all(r.rid != victim.rid for r in eng.scheduler.queue)
    # cancelling a terminal request is a no-op, not an error
    assert not eng.cancel(victim.rid)

    eng.step_until_idle()
    assert all(r.state == State.DONE for r in reqs[:2])
    assert eng.stats.cancelled == 1
    p = eng.pool
    assert p.reserved_blocks == 0
    assert p.free_blocks + p.live_blocks == p.num_blocks


def test_cancel_mid_decode_conserves_and_keeps_survivor_bits(world,
                                                             tmp_path):
    """Cancel one of two decoding requests that SHARE chunk blocks:
    the row is masked, the shared-run reader ref released, pool
    conservation holds, and the survivor's output stays bit-identical
    to a run where nothing was cancelled."""
    cfg, params, kb = world

    def make(tag):
        store = build_store(StoreSpec(ssd_dir=str(tmp_path / tag),
                                      n_chunks=50, m_variants=4,
                                      start_worker=False))
        eng = build_engine(
            _spec(strategy="cachecraft",
                  sched=SchedulerConfig(max_batch_tokens=100_000,
                                        max_decode_batch=4,
                                        max_prefill_batch=2)),
            cfg=cfg, params=params, store=store)
        return eng, _requests(kb, n=2, max_new=8, shared_chunks=True)

    # reference: both run to completion
    ref_eng, ref_reqs = make("ref")
    ref_eng.run(ref_reqs)
    assert all(r.state == State.DONE for r in ref_reqs)

    eng, reqs = make("cut")
    for r in reqs:
        eng.submit(r)
    for _ in range(64):
        eng.step()
        if all(r.state == State.DECODING for r in reqs):
            break
    assert all(r.state == State.DECODING for r in reqs)

    eng.request_cancel(reqs[0].rid)     # thread-safe flag...
    eng.step()                          # ...applied at the next step
    assert reqs[0].state == State.CANCELLED
    assert len(reqs[0].output_tokens) < reqs[0].max_new_tokens
    eng.step_until_idle()

    assert reqs[1].state == State.DONE
    assert reqs[1].output_tokens == ref_reqs[1].output_tokens
    # cancelled prefix matches the uncancelled run bit-for-bit
    n = len(reqs[0].output_tokens)
    assert reqs[0].output_tokens == ref_reqs[0].output_tokens[:n]
    p = eng.pool
    assert p.reserved_blocks == 0
    assert p.free_blocks + p.live_blocks == p.num_blocks


def test_cancel_unknown_rid_is_noop(world):
    cfg, params, _kb = world
    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    assert not eng.cancel(999)
    eng.request_cancel(999)
    eng.step()                          # pending cancel of unknown rid
    assert eng.stats.cancelled == 0


# ---- per-token streaming -----------------------------------------------------
def test_streaming_incremental_and_bit_exact(world):
    cfg, params, kb = world
    ref_eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    ref = _requests(kb, n=2, max_new=6)
    ref_eng.run(ref)

    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    reqs = _requests(kb, n=2, max_new=6)
    for r in reqs:
        eng.submit(r)
    streamed = {r.rid: [] for r in reqs}
    drains_with_tokens = 0
    for _ in range(256):
        if not eng.step():
            break
        ev = eng.drain_tokens()
        drains_with_tokens += bool(ev)
        for rid, tok in ev:
            streamed[rid].append(tok)
    # tokens arrived incrementally (many small drains), not in one burst
    assert drains_with_tokens > 1
    for r, rr in zip(reqs, ref):
        assert r.state == State.DONE
        assert streamed[r.rid] == r.output_tokens == rr.output_tokens


def test_requeue_does_not_duplicate_streamed_tokens(world):
    """Regression: a preemption/requeue cleared ``output_tokens`` and
    the retry re-ran prefill+decode, so ``_emit_token`` re-emitted the
    already-streamed prefix — HTTP clients saw duplicated tokens under
    pool pressure. The ``tokens_emitted`` watermark survives
    ``reset_attempt`` and suppresses the replayed indices."""
    cfg, params, kb = world
    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    r = _requests(kb, n=1, max_new=6)[0]
    eng.submit(r)
    streamed = []
    for _ in range(64):
        eng.step()
        streamed += [t for _, t in eng.drain_tokens()]
        if r.state == State.DECODING and len(r.output_tokens) >= 3:
            break
    assert r.state == State.DECODING and len(streamed) >= 3

    eng._preempt(r)                  # burns the attempt mid-decode
    assert r.output_tokens == [] and r.tokens_emitted == len(streamed)
    eng.scheduler.preempt_requeue(r)   # the path step() takes
    eng.step_until_idle()
    streamed += [t for _, t in eng.drain_tokens()]

    assert r.state == State.DONE
    assert len(r.output_tokens) == r.max_new_tokens
    # the stream saw each output index exactly once, no replayed prefix
    assert streamed == r.output_tokens


# ---- stats payload -----------------------------------------------------------
def test_stats_dict_shape(world):
    cfg, params, kb = world
    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    eng.run(_requests(kb))
    d = eng.stats_dict()
    assert d["completed"] == 2 and d["failed"] == 0
    assert d["cancelled"] == 0
    assert "decode_rebuilds" in d["counters"]
    pool = d["pool"]
    assert pool["free_blocks"] + pool["live_blocks"] \
        + pool["reserved_blocks"] == pool["num_blocks"]


# ---- HTTP server end-to-end --------------------------------------------------
def test_http_server_end_to_end(world):
    from repro.serving.server import CacheCraftServer, ServeClient
    cfg, params, kb = world
    ref_eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    ref = _requests(kb, n=3, max_new=5)
    ref_eng.run(ref)

    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    server = CacheCraftServer(eng)
    server.start()
    try:
        client = ServeClient(server.host, server.port)
        assert client.health()["ok"]

        streams, states = {}, {}

        def reader(rid):
            streams[rid], states[rid] = client.stream(rid)

        threads = []
        for req in _requests(kb, n=3, max_new=5):
            rid = client.submit(req)
            t = threading.Thread(target=reader, args=(rid,),
                                 daemon=True)
            t.start()
            threads.append(t)
        for t in threads:
            t.join(timeout=120)
        assert not any(t.is_alive() for t in threads)

        for rid, rr in enumerate(ref):
            assert states[rid] == State.DONE.value
            assert streams[rid] == rr.output_tokens   # bit-identical

        stats = client.stats()
        assert stats["server"]["submitted"] == 3
        assert stats["server"]["inflight"] == 0
        assert stats["pool"]["reserved_blocks"] == 0
        assert "tenants" in stats
    finally:
        server.shutdown()


def test_http_cancel_mid_decode(world):
    from repro.serving.server import CacheCraftServer, ServeClient
    cfg, params, kb = world
    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    server = CacheCraftServer(eng)
    server.start()
    try:
        client = ServeClient(server.host, server.port)
        req = _requests(kb, n=1, max_new=64)[0]
        rid = client.submit(req)
        acc = []

        def on_token(tok):
            acc.append(tok)
            if len(acc) == 2:
                client.cancel(rid)

        toks, state = client.stream(rid, on_token=on_token)
        assert state == State.CANCELLED.value
        assert 2 <= len(toks) < 64
        stats = client.stats()
        assert stats["cancelled"] == 1
        assert stats["pool"]["reserved_blocks"] == 0
    finally:
        server.shutdown()


def test_unread_streams_and_old_requests_are_garbage_collected(world):
    """A client that submits but never opens its stream (or drops the
    connection early) must not leak the stream queue or the Request
    forever: the dispatcher reaps terminal streams past
    ``stream_ttl_s`` and evicts the oldest finished requests beyond
    ``request_cap``."""
    import time as _time
    from repro.serving.server import CacheCraftServer, ServeClient
    cfg, params, kb = world
    eng = build_engine(_spec(), cfg=cfg, params=params, store=None)
    server = CacheCraftServer(eng, stream_ttl_s=0.0, request_cap=1)
    server.start()
    try:
        client = ServeClient(server.host, server.port)
        reqs = _requests(kb, n=2, max_new=3)
        rid_a = client.submit(reqs[0])     # stream never opened
        deadline = _time.monotonic() + 120
        while _time.monotonic() < deadline:
            if client.stats()["server"]["inflight"] == 0:
                break
            _time.sleep(0.05)
        assert client.stats()["server"]["inflight"] == 0

        rid_b = client.submit(reqs[1])     # its dispatches drive the GC
        toks, state = client.stream(rid_b)
        assert state == State.DONE.value and len(toks) == 3
        with server._lock:
            assert rid_a not in server._streams      # TTL reap
            assert rid_a not in server._done_at
            assert rid_a not in server._requests     # cap eviction
    finally:
        server.shutdown()


# ---- session-structured workloads -------------------------------------------
def test_sessions_have_independent_prefixes(world):
    _cfg, _params, kb = world
    reqs = generate(kb, WorkloadConfig(num_requests=16, qpm=1e9, seed=2,
                                       sessions=4))
    by_session = {}
    for r in reqs:
        by_session.setdefault(r.session, []).append(r)
    assert len(by_session) > 1
    # same session -> same prefix object content; different sessions ->
    # different prefixes (the old generator shared ONE array object)
    for sess, rs in by_session.items():
        for r in rs[1:]:
            np.testing.assert_array_equal(r.system_tokens,
                                          rs[0].system_tokens)
    prefixes = [tuple(rs[0].system_tokens.tolist())
                for rs in by_session.values()]
    assert len(set(prefixes)) == len(prefixes)


def test_multi_turn_history_grows_and_chunks_rotate(world):
    _cfg, _params, kb = world
    wl = WorkloadConfig(num_requests=24, qpm=1e9, seed=2, sessions=3,
                        turns=3, k_chunks=3, history_max=48)
    reqs = generate(kb, wl)
    later_turns = [r for r in reqs if r.turn > 0]
    assert later_turns, "trace produced no multi-turn continuation"
    for r in later_turns:
        # turn > 0 carries accumulated history in the prefix
        assert len(r.system_tokens) > wl.sys_len
        assert len(r.system_tokens) <= wl.sys_len + wl.history_max
    # rotation: a later turn sees the same chunk SET at different
    # positions at least once in the trace (same session qseed pool)
    rotated = False
    first = {}
    for r in reqs:
        key = (r.session,
               frozenset(tuple(c.tolist()) for c in r.chunk_tokens))
        order = [tuple(c.tolist()) for c in r.chunk_tokens]
        if key in first and first[key] != order:
            rotated = True
        first.setdefault(key, order)
    assert rotated


def test_generate_is_deterministic(world):
    _cfg, _params, kb = world
    wl = WorkloadConfig(num_requests=12, qpm=600, seed=4, sessions=3,
                        turns=2, tenants=(TenantSpec("a", 1.0, 5.0),
                                          TenantSpec("b", 1.0, 9.0)))
    a, b = generate(kb, wl), generate(kb, wl)
    for x, y in zip(a, b):
        assert x.arrival_time == y.arrival_time
        assert x.tenant == y.tenant and x.deadline_s == y.deadline_s
        np.testing.assert_array_equal(x.system_tokens, y.system_tokens)
        np.testing.assert_array_equal(x.question_tokens,
                                      y.question_tokens)


def test_tenants_assigned_per_session_with_slos(world):
    _cfg, _params, kb = world
    tenants = (TenantSpec("gold", 1.0, 2.5, max_new_tokens=3),
               TenantSpec("free", 1.0, 9.0))
    reqs = generate(kb, WorkloadConfig(num_requests=32, qpm=1e9, seed=8,
                                       sessions=8, tenants=tenants))
    assert {r.tenant for r in reqs} == {"gold", "free"}
    by_session = {}
    for r in reqs:
        by_session.setdefault(r.session, set()).add(r.tenant)
    assert all(len(ts) == 1 for ts in by_session.values())
    for r in reqs:
        if r.tenant == "gold":
            assert r.deadline_s == 2.5 and r.max_new_tokens == 3
        else:
            assert r.deadline_s == 9.0


def test_single_turn_trace_preserves_legacy_stream(world):
    """Determinism contract: session structure must not consume the
    main arrival rng — a multi-turn config produces the SAME arrival
    times and session draws as the single-turn one."""
    _cfg, _params, kb = world
    a = generate(kb, WorkloadConfig(num_requests=10, qpm=600, seed=3))
    b = generate(kb, WorkloadConfig(num_requests=10, qpm=600, seed=3,
                                    turns=3))
    for x, y in zip(a, b):
        assert x.arrival_time == y.arrival_time
        assert x.session == y.session
