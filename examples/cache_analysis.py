"""Reproduce the paper's §2.3/§3 analyses on a tiny model:

 * contextualization grows with prefix length (Fig. 7),
 * inter vs intra attention distributions decide reusability (Figs. 9/10),
 * output deviation falls as recompute rises (Fig. 15),
 * CCI correlates with deviation (Fig. 12).

Run: PYTHONPATH=src python examples/cache_analysis.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                     # noqa
import jax.numpy as jnp                                        # noqa
import numpy as np                                             # noqa

from repro.configs import get_tiny                             # noqa
from repro.core import scoring                                 # noqa
from repro.core.chunkstore import ChunkStore                   # noqa
from repro.core.prefill import CacheCraftExecutor              # noqa
from repro.core.tiers import TieredStore                       # noqa
from repro.models import model as M                            # noqa
from repro.serving.metrics import relative_deviation           # noqa

cfg = get_tiny("llama3-8b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
V = cfg.vocab_size
chunks = [rng.integers(0, V, 24) for _ in range(6)]

# --- Fig. 7: contextualization vs number of prefix chunks -------------------
print("Fig.7 — hidden-state deviation of chunk C vs #prefix chunks:")
C = chunks[0]
alone = M.forward(cfg, params, tokens=jnp.asarray(C[None]), mode="train")
h_alone = np.asarray(alone.hidden[0])
for n_prefix in (0, 1, 2, 3):
    seq = np.concatenate(chunks[1:1 + n_prefix] + [C])
    out = M.forward(cfg, params, tokens=jnp.asarray(seq[None]),
                    mode="train")
    h_c = np.asarray(out.hidden[0, -len(C):])
    dev = np.linalg.norm(h_c - h_alone) / np.linalg.norm(h_alone)
    print(f"  prefix={n_prefix}: deviation {dev:.3f}")

# --- Figs. 9/10 + Eq. 9-11: inter/intra -> CCI -------------------------------
print("\nEq.9-11 — inter/intra attention and CCI per chunk:")
seq = np.concatenate(chunks[:4])
cids = np.repeat(np.arange(4), [len(c) for c in chunks[:4]])
out = M.forward(cfg, params, tokens=jnp.asarray(seq[None]),
                mode="train", chunk_ids=jnp.asarray(cids[None]),
                collect_stats=True)
stats = np.asarray(out.stats[:, 0])
inter = scoring.inter_matrix(stats, cids, 4)
lengths = [len(c) for c in chunks[:4]]
for i in range(1, 4):
    sc = scoring.chunk_scores(inter, lengths, i,
                              [f"h{j}" for j in range(i)],
                              np.zeros(lengths[i]))
    print(f"  chunk {i}: a_bar={sc.a_bar:.4f} b_bar={sc.b_bar:.4f} "
          f"CCI={sc.cci:.3f}")

# --- Fig. 15: deviation vs recompute fraction --------------------------------
print("\nFig.15 — output deviation vs recompute fraction:")
store = ChunkStore(TieredStore(1 << 30, 1 << 30, tempfile.mkdtemp()),
                   100, 5)
sys_t = rng.integers(0, V, 8)
q1, q2 = rng.integers(0, V, 12), rng.integers(0, V, 12)
CacheCraftExecutor(cfg, params, store, use_focus=False).process(
    sys_t, chunks[:3], q1)
oracle = CacheCraftExecutor(cfg, params, None, strategy="all").process(
    sys_t, [chunks[1], chunks[0], chunks[3]], q2)
for frac in (0.0, 0.2, 0.4, 0.6, 0.8, 1.0):
    ex = CacheCraftExecutor(cfg, params, store, use_focus=False,
                            force_recompute_fraction=frac,
                            store_fixed_variants=False,
                            store_new_chunks=False)
    r = ex.process(sys_t, [chunks[1], chunks[0], chunks[3]], q2)
    print(f"  recompute {frac:.0%}: deviation "
          f"{relative_deviation(r.logits_last, oracle.logits_last):.4f}")
