"""Oracle for the SSD intra-chunk kernel (pure jnp, mirrors
models.layers.ssd_chunked's intra-chunk + chunk-state math)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ssd_intra_ref(xdt, log_a, B_mat, C_mat):
    """xdt [nC,L,H,P], log_a [nC,L,H], B/C [nC,L,N]."""
    nC, L, H, P = xdt.shape
    la = log_a.astype(jnp.float32)
    cum = jnp.cumsum(la, axis=1)                          # [nC,L,H]
    seg = cum[:, :, None, :] - cum[:, None, :, :]         # [nC,L,L,H] (i,j)
    mask = np.tril(np.ones((L, L), bool))[None, :, :, None]
    decay = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("cin,cjn->cij", C_mat.astype(jnp.float32),
                        B_mat.astype(jnp.float32))
    y = jnp.einsum("cijh,cij,cjhp->cihp", decay, scores,
                   xdt.astype(jnp.float32))
    total = cum[:, -1]                                    # [nC,H]
    decay_out = jnp.exp(total[:, None] - cum)             # [nC,L,H]
    st = jnp.einsum("cln,clh,clhp->chpn", B_mat.astype(jnp.float32),
                    decay_out, xdt.astype(jnp.float32))
    return y, st
