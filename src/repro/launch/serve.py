"""Serving launcher: batch replay or a live HTTP server, both built
through the one typed front door (``serving.api.EngineSpec``).

Batch replay (default): generate a synthetic RAG workload, run it
through the engine with continuous batching, print per-request and
aggregate stats::

    python -m repro.launch.serve --requests 24 --qpm 240

Online serving (``--serve``): boot the engine on a background stepping
thread behind the stdlib HTTP API (see ``serving/server.py`` for the
threading/ownership contract), then drive it from anywhere::

    # terminal 1 — tiny config, random-init params, port 8763
    python -m repro.launch.serve --serve --port 8763

    # terminal 2 — submit, stream tokens as NDJSON, inspect stats
    curl -s localhost:8763/v1/submit -d '{
        "system_tokens": [1,2,3], "chunk_tokens": [[4,5,6],[7,8]],
        "question_tokens": [9,10], "max_new_tokens": 8,
        "tenant": "gold", "deadline_s": 2.0}'
    # -> {"rid": 0}
    curl -sN localhost:8763/v1/stream/0      # {"token": ...} per line,
                                             # then {"done": true, ...}
    curl -s -X POST localhost:8763/v1/cancel/0
    curl -s localhost:8763/stats | python -m json.tool

Full-size configs: ``--full`` (the old ``--tiny`` flag was
``store_true`` with ``default=True`` — permanently on, so full-size
was unreachable from the CLI).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.serving.api import EngineSpec, build_engine
from repro.serving.rag import KnowledgeBase
from repro.serving.workload import TenantSpec, WorkloadConfig, generate


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    # engine construction (consumed by EngineSpec.from_args)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default is the tiny one)")
    from repro.core.strategies import STRATEGIES
    ap.add_argument("--strategy", default="cachecraft",
                    choices=tuple(STRATEGIES),
                    help="recompute strategy (core.strategies registry): "
                         + ", ".join(STRATEGIES))
    ap.add_argument("--recompute", type=float, default=None)
    ap.add_argument("--no-focus", action="store_true")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--layerwise-load", action="store_true")
    ap.add_argument("--pool-blocks", type=int, default=8192)
    ap.add_argument("--max-batch-tokens", type=int, default=8192)
    ap.add_argument("--max-decode-batch", type=int, default=4)
    ap.add_argument("--tier-dtypes", default=None,
                    help='per-tier storage codecs, e.g. "cpu=int8,ssd=fp8"')
    ap.add_argument("--params", default=None,
                    help="checkpoint dir with trained params")
    ap.add_argument("--seed", type=int, default=0)
    # workload (batch replay)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--qpm", type=float, default=240)
    ap.add_argument("--kb-chunks", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--turns", type=int, default=1,
                    help=">1: multi-turn sessions with growing history")
    ap.add_argument("--tenants", default=None,
                    help='mixed-tenant trace, e.g. "gold:3:2.0,free:1:8.0" '
                         "(name:weight:deadline_s)")
    # online serving
    ap.add_argument("--serve", action="store_true",
                    help="run the HTTP server instead of batch replay")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8763)
    return ap


def parse_tenants(s):
    if not s:
        return None
    out = []
    for part in s.split(","):
        name, weight, deadline = (part.split(":") + ["1", "0"])[:3]
        out.append(TenantSpec(name, float(weight), float(deadline)))
    return out


def main():
    args = make_parser().parse_args()
    spec = EngineSpec.from_args(args)
    eng = build_engine(spec)
    kb = KnowledgeBase(num_chunks=args.kb_chunks,
                       vocab_size=eng.cfg.vocab_size, seed=args.seed)

    if args.serve:
        from repro.serving.server import CacheCraftServer
        srv = CacheCraftServer(eng, host=args.host, port=args.port).start()
        print(f"serving {args.arch}{'' if args.full else ' (tiny)'} "
              f"strategy={spec.strategy} on {srv.url}")
        print("routes: POST /v1/submit | GET /v1/stream/<rid> | "
              "POST /v1/cancel/<rid> | GET /health | GET /stats")
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            print("\nshutting down...")
            srv.shutdown()
        return

    reqs = generate(kb, WorkloadConfig(
        num_requests=args.requests, qpm=args.qpm, seed=args.seed,
        max_new_tokens=args.max_new, k_chunks=5, turns=args.turns,
        tenants=parse_tenants(args.tenants)))
    t0 = time.time()
    stats = eng.run(reqs)
    wall = time.time() - t0
    done = [r for r in reqs if r.e2e_latency is not None]
    print(f"\n== {spec.strategy} | {args.requests} reqs @ {args.qpm} QPM ==")
    print(f"completed {stats.completed} failed {stats.failed} "
          f"wall {wall:.1f}s simclock {stats.clock:.2f}s")
    print(f"prefill tokens: total {stats.prefill_tokens_total} "
          f"computed {stats.prefill_tokens_computed} "
          f"(saved {1 - stats.prefill_tokens_computed / max(1, stats.prefill_tokens_total):.1%})")
    if done:
        print(f"TTFT mean {np.mean([r.ttft for r in done])*1e3:.1f}ms "
              f"p99 {np.percentile([r.ttft for r in done], 99)*1e3:.1f}ms")
        print(f"e2e mean {np.mean([r.e2e_latency for r in done]):.3f}s  "
              f"throughput {len(done)/max(stats.clock, 1e-9):.2f} req/s")
    if eng.store:
        store = eng.store
        print(f"store: {store.num_variants()} variants over "
              f"{len(store.table)} chunks, evictions {store.evictions}, "
              f"tier hits {store.tiers.stats['hits']}")


if __name__ == "__main__":
    main()
